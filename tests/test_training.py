"""Training substrate: optimizer math, loss descent, checkpoints, LR."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import ByteTokenizer, lm_batches
from repro.models import build_model
from repro.training import (AdamW, load_checkpoint, make_lr_schedule,
                            make_train_step, save_checkpoint)


def test_adamw_matches_reference_on_scalar_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.array([2.0])}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state = opt.update(grads, state, params)
    assert abs(float(params["w"][0])) < 0.5


def test_loss_decreases_100m_scale_family():
    cfg = get_config("llama3.2-1b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(m, opt))
    it = lm_batches(cfg.vocab_size, 4, 64, seed=0)
    losses = []
    for _ in range(10):
        b = next(it)
        params, state, mt = step(params, state,
                                 {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(mt["loss"]))
    assert losses[-1] < losses[0] - 0.2


def test_grad_accum_equivalence():
    """accum=2 over a 4-batch equals accum=1 up to numerics."""
    cfg = get_config("qwen2-1.5b", reduced=True)
    m1 = build_model(cfg.with_overrides(grad_accum=1))
    m2 = build_model(cfg.with_overrides(grad_accum=2))
    params = m1.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3)
    b = next(lm_batches(cfg.vocab_size, 4, 32, seed=1))
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    p1, _, mt1 = make_train_step(m1, opt)(params, opt.init(params), batch)
    p2, _, mt2 = make_train_step(m2, opt)(params, opt.init(params), batch)
    assert float(mt1["loss"]) == pytest.approx(float(mt2["loss"]), rel=1e-2)
    for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b2, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_lr_schedule_shape():
    s = make_lr_schedule(warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=0.01)
    assert float(s(100)) == pytest.approx(0.1, abs=0.05)
    assert float(s(55)) < float(s(10))


def test_checkpoint_roundtrip_preserves_dtypes():
    cfg = get_config("mamba2-2.7b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    opt = AdamW()
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params, state, step=42)
        p2, s2, step = load_checkpoint(path, params, state)
        assert step == 42
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-2, atol=1e-3)


def test_tokenizer_roundtrip():
    t = ByteTokenizer()
    s = "Hello, SageSched! 你好"
    ids = t.encode(s, add_bos=True, add_eos=True)
    assert ids[0] == t.bos_id and ids[-1] == t.eos_id
    assert t.decode(ids) == s
