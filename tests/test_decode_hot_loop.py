"""Device-resident decode hot loop: one jitted, donated, bucket-stable
engine step.

Load-bearing assertions:

  * the fused jitted step (``step_mode="fused"``) is token-identical to
    the orchestrated per-step engine for every servable model family
    (dense / vlm / moe / ssm / hybrid), in both swap and recompute
    preemption modes, including chunked-prefill mixed steps (greedy);
  * the ``lax.fori_loop`` multi-step variant (N decode tokens per host
    round-trip) emits the same streams while issuing fewer device calls;
  * the compile set is bounded: the fused step compiles at most once per
    (family, bucket) across a churny admit/evict/finish workload —
    counted against the REAL jit cache — and decode logits are
    bit-identical across neighboring batch/page bucket sizes;
  * SSM/hybrid prefill over pow2-padded buffers with the true-length
    mask is bit-identical to the unpadded scan (ROADMAP item), and the
    engine's atomic-prefill jit compiles once per bucket, not once per
    distinct context length;
  * fused stochastic sampling is seeded per (request, position), so swap
    and recompute preemption produce identical streams even at
    temperature > 0 — a guarantee the host-RNG orchestrated path never
    had.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.models import build_model
from repro.serving import RequestState, ServeRequest, ServingEngine
from repro.serving.engine import _ladder_size, _pow2_bucket

FAMILIES = ["llama3.2-1b", "internvl2-76b", "olmoe-1b-7b", "mamba2-2.7b",
            "zamba2-1.2b"]


def _run_engine(arch, *, step_mode, preemption_mode="swap", decode_steps=1,
                n=3, cap=40, n_slots=2, temperature=0.0, chunk=None,
                mtps=None, max_steps=6000):
    cfg = get_config(arch, reduced=True)
    o = OraclePredictor()
    for i in range(n):
        o.register(f"p{i}", LengthDistribution(np.array([6 + 2 * i]),
                                               np.array([1.0])))
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("sagesched"), predictor=o),
        n_slots=n_slots, max_seq_len=96, capacity_tokens=cap,
        block_size=8, preemption_mode=preemption_mode, prefill_chunk=chunk,
        max_tokens_per_step=mtps, seed=0, step_mode=step_mode,
        decode_steps=decode_steps)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(n):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                             int(rng.integers(6, 11)))]
        reqs.append(ServeRequest(f"r{i}", f"p{i}", toks,
                                 max_new_tokens=6 + 2 * i,
                                 temperature=temperature, eos_token=1,
                                 arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=max_steps)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    return eng, [r.output_tokens for r in reqs]


# ------------------------------------------------------ engine parity

@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("pmode", ["swap", "recompute"])
def test_fused_matches_orchestrated(arch, pmode):
    """The acceptance criterion: the fused jitted step is token-identical
    to the orchestrated per-step path for every family and both
    preemption modes (greedy)."""
    _, want = _run_engine(arch, step_mode="orchestrated",
                          preemption_mode=pmode)
    ef, got = _run_engine(arch, step_mode="fused", preemption_mode=pmode)
    assert got == want
    assert ef.metrics.fused_steps > 0
    assert ef.metrics.decode_tokens == sum(len(t) for t in got)


def test_fused_matches_orchestrated_chunked_mixed():
    """Chunked-prefill steps mix prefill chunks with the fused decode
    batch under one token budget; streams still match the orchestrated
    engine."""
    _, want = _run_engine("llama3.2-1b", step_mode="orchestrated", cap=96,
                          n=5, n_slots=4, chunk=4, mtps=12)
    _, got = _run_engine("llama3.2-1b", step_mode="fused", cap=96,
                         n=5, n_slots=4, chunk=4, mtps=12)
    _, got_multi = _run_engine("llama3.2-1b", step_mode="fused", cap=96,
                               n=5, n_slots=4, chunk=4, mtps=12,
                               decode_steps=4)
    assert got == want
    assert got_multi == want


def test_multi_step_matches_single_and_batches_roundtrips():
    """decode_steps=N decodes N tokens per host round-trip inside one
    lax.fori_loop device call: same greedy streams, fewer fused calls,
    fewer scheduler interventions — even under forced preemption."""
    e1, want = _run_engine("llama3.2-1b", step_mode="fused", n=4)
    e4, got = _run_engine("llama3.2-1b", step_mode="fused", n=4,
                          decode_steps=4)
    assert got == want
    assert e4.metrics.fused_steps < e1.metrics.fused_steps
    assert e4.metrics.decode_tokens == e1.metrics.decode_tokens


def test_fused_stochastic_swap_equals_recompute():
    """Sampling keys are folded from (request seed, position), never the
    slot or the preemption history: stochastic streams survive
    preemption-mode changes bit-for-bit."""
    es, a = _run_engine("llama3.2-1b", step_mode="fused", temperature=0.7)
    er, b = _run_engine("llama3.2-1b", step_mode="fused", temperature=0.7,
                        preemption_mode="recompute")
    assert a == b
    assert es.metrics.preemptions > 0 and er.metrics.preemptions > 0


# -------------------------------------------------- bucket stability

def test_compile_counter_bounded_under_churn():
    """A churny admit/evict/finish workload walks the active-lane and
    page counts up and down; the fused step may compile at most once per
    bucket (the real jit cache is the counter), and a second wave of
    churn adds ZERO new compiles."""
    cfg = get_config("llama3.2-1b", reduced=True)
    o = OraclePredictor()
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("sagesched"), predictor=o),
        n_slots=8, max_seq_len=96, capacity_tokens=192, block_size=8,
        seed=0, step_mode="fused")
    def wave(tag, n):
        # fresh identically-seeded rng per wave: wave "b" replays wave
        # "a"'s exact shapes and token values (ids differ), so the jit
        # cache growing on it would be a genuine recompile regression
        rng = np.random.default_rng(11)
        reqs = []
        for i in range(n):
            o.register(f"{tag}{i}", LengthDistribution(
                np.array([3 + (i % 7)]), np.array([1.0])))
            toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                                 int(rng.integers(4, 20)))]
            reqs.append(ServeRequest(f"{tag}{i}", f"{tag}{i}", toks,
                                     max_new_tokens=3 + (i % 7),
                                     temperature=0.0, eos_token=1,
                                     arrival=float(i) * 1e-3))
        eng.submit_batch(reqs)
        eng.run_until_done(max_steps=8000)
        return reqs

    wave("a", 12)
    bound = eng.max_fused_compiles()
    first = eng.fused_compile_count
    if first < 0:
        pytest.skip("jax build exposes no jit cache-size counter")
    assert 0 < first <= bound
    # same shapes revisited -> the jit cache must not grow
    wave("b", 12)
    assert eng.fused_compile_count == first


def test_fused_compile_bound_is_ladder_product():
    assert _pow2_bucket(1) == 1 and _pow2_bucket(3) == 4
    assert _pow2_bucket(5, cap=6) == 6
    assert _ladder_size(8) == 4          # 1, 2, 4, 8
    assert _ladder_size(12) == 5         # 1, 2, 4, 8, 12(capped)


def test_decode_bit_identical_across_neighbor_buckets():
    """Lane gathering + table-width bucketing must be pure relayouts: a
    request's decode logits are bit-identical whether it rides a 1-, 2-
    or 4-wide batch bucket and a tight or padded page bucket."""
    cfg = get_config("llama3.2-1b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    S, page = 13, 8
    toks = rng.integers(3, cfg.vocab_size, (1, S)).astype(np.int32)
    _, cache = m.prefill(params, {"tokens": jnp.asarray(toks)})
    kd = np.asarray(cache["k"], np.float32)[:, 0]        # (L, S, KV, dh)
    vd = np.asarray(cache["v"], np.float32)[:, 0]
    L, _, KV, dh = kd.shape
    n_pages = 8
    blocks = [2, 5]                                      # pages for S=13
    phys = np.array([blocks[p // page] * page + p % page
                     for p in range(S)])
    flatk = np.zeros((L, n_pages * page, KV, dh), np.float32)
    flatv = np.zeros_like(flatk)
    flatk[:, phys] = kd[:, :S]
    flatv[:, phys] = vd[:, :S]
    pool_k = flatk.reshape(L, n_pages, page, KV, dh)
    pool_v = flatv.reshape(L, n_pages, page, KV, dh)

    outs = []
    for B, P in ((1, 2), (2, 2), (4, 4), (4, 8)):
        bt = np.zeros((B, P), np.int32)
        bt[0, :2] = blocks
        cl = np.zeros(B, np.int32)
        cl[0] = S - 1
        tok = np.zeros((B, 1), np.int32)
        tok[0, 0] = toks[0, -1]
        pc = {"k": jnp.asarray(pool_k, jnp.bfloat16),
              "v": jnp.asarray(pool_v, jnp.bfloat16)}
        logits, _ = m.decode_step_paged(
            params, jnp.asarray(tok), pc, jnp.asarray(cl),
            jnp.asarray(bt), page_size=page)
        outs.append(np.asarray(logits[0], np.float32))
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0], other)


# ------------------------------------------- padded recurrent prefill

@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-1.2b"])
def test_padded_prefill_bit_identical_to_unpadded(arch):
    """ROADMAP item: the true-length mask (dt = 0 at pads, decay exactly
    1) makes pow2-padded prefill bit-identical to the unpadded scan —
    recurrent state, conv tail, and (hybrid) attention KV at valid
    positions."""
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    S = 23
    toks = rng.integers(3, cfg.vocab_size, (1, S)).astype(np.int32)
    _, want = m.prefill(params, {"tokens": jnp.asarray(toks)})
    for spad in (32, 64):
        tp = np.zeros((1, spad), np.int32)
        tp[0, :S] = toks
        _, got = m.prefill(params, {
            "tokens": jnp.asarray(tp),
            "lengths": jnp.asarray([S], jnp.int32)})
        np.testing.assert_array_equal(np.asarray(want["ssm"]["ssd"]),
                                      np.asarray(got["ssm"]["ssd"]))
        np.testing.assert_array_equal(np.asarray(want["ssm"]["conv"]),
                                      np.asarray(got["ssm"]["conv"]))
        if "k" in want:
            np.testing.assert_array_equal(
                np.asarray(want["k"], np.float32)[:, :, :S],
                np.asarray(got["k"], np.float32)[:, :, :S])


def test_recurrent_prefill_compiles_per_bucket_not_per_length():
    """Distinct context lengths inside one pow2 bucket share one XLA
    compile of the engine's atomic-prefill jit (the seed engine compiled
    once per distinct length)."""
    cfg = get_config("mamba2-2.7b", reduced=True)
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs")),
        n_slots=2, max_seq_len=96, seed=0)
    rng = np.random.default_rng(3)
    reqs = []
    for i, plen in enumerate((7, 9, 12, 14)):     # all in the 32-bucket
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size, plen)]
        reqs.append(ServeRequest(f"c{i}", f"c{i}", toks, max_new_tokens=3,
                                 temperature=0.0, eos_token=1,
                                 arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=2000)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    counter = getattr(eng._prefill_fn, "_cache_size", None)
    if counter is None:
        pytest.skip("jax build exposes no jit cache-size counter")
    assert counter() == 1
