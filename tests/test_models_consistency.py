"""Prefill + incremental decode must equal the full-sequence forward —
the serving path's core correctness invariant, checked for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    # generous MoE capacity so no tokens drop in either mode
    cfg = get_config(arch, reduced=True).with_overrides(capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 17
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S + 1)), jnp.int32)
    bf, bp = {"tokens": toks}, {"tokens": toks[:, :S]}
    extra = 0
    if cfg.family == "vlm":
        pt = jnp.asarray(rng.normal(0, 0.02, (B, 4, cfg.d_model)),
                         jnp.bfloat16)
        bf["patches"] = pt
        bp["patches"] = pt
        extra = 4
    if cfg.family == "encdec":
        fr = jnp.asarray(rng.normal(0, 0.02, (B, 8, cfg.d_model)),
                         jnp.bfloat16)
        bf["frames"] = fr
        bp["frames"] = fr
    logits_full, _, _ = m.forward(params, bf, remat=False)
    want = logits_full[:, -1, :].astype(jnp.float32)
    _, cache = m.prefill(params, bp)
    cache = {k: (jnp.pad(v, [(0, 0)] * 2 + [(0, 4)] + [(0, 0)] * 2)
                 if k in ("k", "v") else v) for k, v in cache.items()}
    cl = jnp.full((B,), S + extra, jnp.int32)
    if cfg.family == "encdec":
        cl = jnp.full((B,), S, jnp.int32)
    got, _ = m.decode_step(params, toks[:, S:S + 1], cache, cl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=5e-2, atol=2e-2)


def test_sliding_window_matches_full_when_window_covers():
    cfg = get_config("llama3.2-1b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(1).integers(3, 512, (1, 24)),
                       jnp.int32)
    full, _, _ = m.forward(params, {"tokens": toks}, remat=False)
    cfg_w = cfg.with_overrides(attention_kind="sliding_window", window=64)
    mw = build_model(cfg_w)
    win, _, _ = mw.forward(params, {"tokens": toks}, remat=False)
    np.testing.assert_allclose(np.asarray(win, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=1e-2)


def test_sliding_window_differs_beyond_window():
    cfg = get_config("llama3.2-1b", reduced=True).with_overrides(
        attention_kind="sliding_window", window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(1).integers(3, 512, (1, 40)),
                       jnp.int32)
    win, _, _ = m.forward(params, {"tokens": toks}, remat=False)
    full_cfg = cfg.with_overrides(attention_kind="full")
    full, _, _ = build_model(full_cfg).forward(params, {"tokens": toks},
                                               remat=False)
    diff = float(jnp.max(jnp.abs(win.astype(jnp.float32)
                                 - full.astype(jnp.float32))))
    assert diff > 1e-3  # the window must actually mask something


def test_remat_does_not_change_loss():
    cfg = get_config("qwen2-1.5b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(2).integers(3, 512, (2, 32)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = m.loss_fn(params, batch, remat=True)
    l2, _ = m.loss_fn(params, batch, remat=False)
    assert float(jnp.abs(l1 - l2)) < 1e-4
