#!/usr/bin/env python3
"""Fail CI on broken relative links in Markdown files.  Stdlib only.

Checks every ``[text](target)`` and bare ``<target>`` style link in the
given files/directories:

  * external schemes (http/https/mailto) are skipped — CI must not
    depend on network reachability;
  * absolute paths are rejected (docs must stay relocatable);
  * relative targets (after stripping ``#fragment``) must exist on disk,
    resolved against the linking file's directory;
  * intra-file anchors (``#section``) are validated against the target
    file's ATX headings using GitHub's slug rules (lowercase, spaces to
    dashes, punctuation dropped).

Usage:  python tools/check_links.py README.md docs [more files/dirs...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
IMG_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)                # inline formatting
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)  # links -> text
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(md: Path, repo_root: Path) -> list[str]:
    errors = []
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    targets = [m.group(1) for m in LINK_RE.finditer(text)]
    targets += [m.group(1) for m in IMG_RE.finditer(text)]
    for raw in targets:
        if raw.startswith(SKIP_SCHEMES):
            continue
        path_part, _, fragment = raw.partition("#")
        if raw.startswith("/"):
            errors.append(f"{md}: absolute link {raw!r} (use relative)")
            continue
        if path_part:
            target = (md.parent / path_part).resolve()
            if not target.exists():
                errors.append(f"{md}: broken link {raw!r} "
                              f"(no such file {path_part!r})")
                continue
            if repo_root not in target.parents and target != repo_root:
                errors.append(f"{md}: link {raw!r} escapes the repository")
                continue
        else:
            target = md
        if fragment and target.suffix == ".md" and target.is_file():
            if fragment not in anchors_of(target):
                errors.append(f"{md}: broken anchor {raw!r} "
                              f"(no heading slug {fragment!r} in "
                              f"{target.name})")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        argv = ["README.md", "docs"]
    repo_root = Path.cwd().resolve()
    files: list[Path] = []
    errors: list[str] = []
    for arg in argv:
        p = Path(arg)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.is_file():
            files.append(p)
        else:
            # a vanished target must FAIL the job, not silently shrink
            # its scope to nothing
            errors.append(f"argument {arg!r} does not exist")
    for md in files:
        errors.extend(check_file(md.resolve(), repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown file(s): "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
